"""Assemble EXPERIMENTS.md from dry-run artifacts, perf variants, and the
benchmark CSV.

  PYTHONPATH=src python scripts/make_experiments.py [--bench bench_output.txt]
"""

from __future__ import annotations

import argparse
import json
import pathlib

from repro.launch.mesh import HW
from repro.launch.roofline import analyze_cell, load_cells

OUT = pathlib.Path("EXPERIMENTS.md")
DRY = pathlib.Path("experiments/dryrun")
PERF = pathlib.Path("experiments/perf")


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 0.01:
        return f"{x:.3f}"
    return f"{x:.2e}"


def roofline_table(mesh_tag: str) -> str:
    cells = load_cells(str(DRY), mesh_tag)
    hdr = ("| arch | shape | dominant | compute s | memory s | collective s "
           "| useful ratio | roofline frac | peak GiB | fits |")
    sep = "|" + "---|" * 10
    rows = [hdr, sep]
    for key in sorted(cells):
        c = cells[key]
        if "skipped" in c:
            rows.append(f"| {c['arch']} | {c['shape']} | SKIP | "
                        f"{c['skipped'][:64]} ||||||||")
            continue
        if "error" in c:
            rows.append(f"| {c['arch']} | {c['shape']} | ERROR |||||||||")
            continue
        rows.append(
            f"| {c['arch']} | {c['shape']} | **{c['dominant']}** | "
            f"{fmt_s(c['t_compute_s'])} | {fmt_s(c['t_memory_s'])} | "
            f"{fmt_s(c['t_collective_s'])} | {c['useful_ratio']:.3f} | "
            f"{c['roofline_fraction']:.3f} | {c['memory_peak_gib']:.1f} | "
            f"{'Y' if c['fits_hbm'] else 'N'} |")
    return "\n".join(rows)


def dryrun_summary(mesh_tag: str) -> str:
    rows = ["| arch | shape | compile s | peak GiB | HLO FLOPs/dev | "
            "collective GiB/dev |", "|---|---|---|---|---|---|"]
    for p in sorted(DRY.glob(f"*__{mesh_tag}.json")):
        d = json.loads(p.read_text())
        if "skipped" in d:
            rows.append(f"| {d['arch']} | {d['shape']} | SKIP | "
                        f"{d['skipped'][:60]} |||")
            continue
        if "error" in d:
            rows.append(f"| {d['arch']} | {d['shape']} | ERROR ||||")
            continue
        coll = sum(d.get("collectives", {}).values()) / 2**30
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d.get('compile_s', '-')} | "
            f"{d['memory']['peak_bytes'] / 2**30:.2f} | "
            f"{d['cost'].get('flops', 0):.3g} | {coll:.2f} |")
    return "\n".join(rows)


def perf_cell(path):
    d = json.loads(path.read_text())
    coll = sum(d.get("collectives", {}).values())
    return {
        "peak_gib": d["memory"]["peak_bytes"] / 2**30,
        "bytes": d["cost"].get("bytes accessed", 0.0),
        "flops": d["cost"].get("flops", 0.0),
        "coll_gib": coll / 2**30,
        "t_mem_ms": d["cost"].get("bytes accessed", 0.0) / HW.HBM_BW * 1e3,
        "t_coll_ms": coll / HW.ICI_BW * 1e3,
    }


def paper_section(bench_path: str | None) -> str:
    if not bench_path or not pathlib.Path(bench_path).exists():
        return "_(run `python -m benchmarks.run | tee bench_output.txt` and " \
               "re-generate)_"
    lines = pathlib.Path(bench_path).read_text().splitlines()
    keep = [l for l in lines if l.startswith(("fig", "table")) or
            l.startswith("#")]
    return "```\n" + "\n".join(keep) + "\n```"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="bench_output.txt")
    args = ap.parse_args()

    def pc(name):
        p = PERF / name
        return perf_cell(p) if p.exists() else None

    cr_base = json.loads(
        (DRY / "command-r-plus-104b__train_4k__single.json").read_text())
    cr_opt = pc("command-r-plus-104b__train_4k__opt.json")
    lv_base = json.loads(
        (DRY / "llama-3.2-vision-11b__prefill_32k__single.json").read_text())
    lv_opt = pc("llama-3.2-vision-11b__prefill_32k__opt.json")
    kv_base = pc("bourbon_kv__get__baseline.json")
    kv_opt = pc("bourbon_kv__get__opt.json")

    cr_base_peak = cr_base["memory"]["peak_bytes"] / 2**30
    lv_base_coll = sum(lv_base["collectives"].values())

    md = f"""# EXPERIMENTS

Hardware model: TPU v5e — {HW.PEAK_BF16_FLOPS/1e12:.0f} TFLOP/s bf16,
{HW.HBM_BW/1e9:.0f} GB/s HBM, {HW.ICI_BW/1e9:.0f} GB/s ICI
({HW.DCI_BW/1e9:.0f} GB/s DCI cross-pod), {HW.HBM_BYTES/2**30:.0f} GiB HBM
per chip.  Meshes: single pod (data=16, model=16) = 256 chips; multi-pod
(pod=2, data=16, model=16) = 512 chips (placeholder host devices — this
container is CPU-only; every number below is derived from
`.lower().compile()` artifacts, not wall-clock).

## §Dry-run

Every (architecture x input-shape) cell lowers AND compiles on both meshes
(`repro.launch.dryrun`).  `long_500k` is skipped for pure full-attention
architectures per DESIGN.md §Arch-applicability (7 documented skips of the
40 cells); xlstm / hymba / mixtral(SWA) run it.  The `bourbon_kv` row is the
paper's own workload: a 2^30-key range-partitioned learned-index snapshot
serving 2^20-probe batched GETs.

Methodology notes (verified empirically, see tests/test_roofline.py):
* `cost_analysis()` reports **per-device** numbers and counts while bodies
  **once** — FLOPs/bytes therefore come from *metering builds* (unrolled
  layers + unrolled real-size chunk loops, `--metering`) at n_units=1,2 and
  the depth-delta extrapolation `total = u2 + (U-2)(u2-u1)`.
* Collective bytes come from a trip-count-aware walk of the compiled HLO
  (launch/hlo_parse.py), on the full (scanned) build.
* memory_analysis comes from the full build (the metering build's memory is
  not representative).
* Known undercount: sLSTM's per-timestep scan body is counted once
  (~1% of xlstm FLOPs — its projections are hoisted outside the scan).

### single pod (16x16)

{dryrun_summary("single")}

### multi-pod (2x16x16)

{dryrun_summary("multi")}

## §Roofline (single pod, per device)

compute = FLOPs/chip / peak; memory = bytes/chip / HBM bw; collective =
collective bytes/chip / ICI bw.  useful ratio = MODEL_FLOPS (6·N·D train,
2·N·D prefill, 2·N_active·B decode) / HLO FLOPs — remat recompute, CE, and
dispatch overheads show up here.  roofline frac = ideal model-FLOP time /
dominant term.

{roofline_table("single")}

Reading the table:
* **Every cell is memory-term-dominant under the XLA cost model.** XLA's
  "bytes accessed" charges every op's operands+results as HBM traffic; on a
  real TPU a large share of those bytes hit VMEM/registers after fusion, so
  the memory column is an upper bound and the compute column is the better
  wall-clock predictor for the large dense cells (useful_ratio 0.45-0.76).
* Decode cells have tiny roofline fractions by construction (one token per
  step against the whole cache/params — they are latency, not throughput,
  cells).  MLA's compressed cache shows up as deepseek's small decode
  memory term.
* What would move the dominant (memory) term: fused attention/SSM Pallas
  kernels (collapse per-op HBM round-trips — the same fusion the lookup
  kernels do for the store), bf16 collective payloads, and the §Perf items
  below.

## §Perf — three hillclimbed cells

Strict sequence per cell: paper-faithful/default BASELINE recorded first,
then hypothesis -> change -> re-lower -> confirmed/refuted.

### 1. bourbon_kv GET (most representative of the paper)

Baseline (paper-faithful tensorized lookup, broadcast segment compare +
all-reduce combine) vs optimized:

| variant | HLO bytes/dev | t_memory | collective payload | t_collective |
|---|---|---|---|---|
| baseline (compare + all-reduce) | {kv_base['bytes']:.3g} | {kv_base['t_mem_ms']:.2f} ms | {kv_base['coll_gib']*1024:.1f} MiB | {kv_base['t_coll_ms']:.3f} ms |
| optimized (bisect + int8 + reduce-scatter) | {kv_opt['bytes']:.3g} | {kv_opt['t_mem_ms']:.2f} ms | {kv_opt['coll_gib']*1024:.1f} MiB | {kv_opt['t_coll_ms']:.3f} ms |

* H1 (napkin: the (B=2^20, S=512) f64 segment compare moves ~8.6 GB of the
  9.6 GB total) -> replace with log2(S) bisect gathers -> bytes 9.63e9 ->
  8.61e8 (**11.2x**), temp 4.13 -> 0.17 GiB.  **Confirmed.**
* H2 (results need only reach the probe's origin shard; found fits int8)
  -> psum -> psum_scatter + int8 -> collective 26.5 -> 9.1 MiB (**2.9x**),
  t_coll 0.556 -> 0.191 ms.  **Confirmed.**
* Stopping: remaining memory term is the delta-window gather itself (the
  paper's own bound) — further ideas (<5% projected x3): int32 probes
  (keys are int64 by spec), smaller delta (8 is the paper's optimum).
* Net: GET step lower bound 11.8 ms -> 1.0 ms (**11.8x**); cluster
  throughput bound ~10^9 lookups/s on 256 chips.

### 2. command-r-plus-104b x train_4k (worst memory term / did not fit)

| variant | peak GiB | fits 16 GiB |
|---|---|---|
| baseline (remat=full, f32 accum, microbatch 16) | {cr_base_peak:.1f} | N |
| + scan-param FSDP constraint (H1) | {cr_base_peak:.1f} | N |
| + nested sqrt(L) remat (H2) | 16.2 | N (marginal) |
| + bf16 gradient accumulation (H3) | {cr_opt['peak_gib']:.1f} | **Y** |

* H1 (XLA hoists a whole-stack FSDP all-gather; pin per-layer shards inside
  the scan) -> **Refuted**: identical memory; the HLO shows only 2.2 GiB of
  all-gather — the 12 GiB buffer was the per-layer saved block inputs
  stacked by the scan (an f32 view inside a fusion; live buffer is bf16).
* H2 (64 saved block inputs at 96 MiB each = 6 GiB; sqrt(L) two-level
  checkpointing keeps G + L/G inputs) -> 27.9 -> 16.2 GiB.  **Confirmed**
  (cost: one extra forward, ~ +11% step FLOPs — visible in useful_ratio).
* H3 (f32 accumulation buffer = 1.6 GiB; bf16 halves it; mean-of-16
  microbatch gradients tolerates bf16) -> 16.2 -> 15.4 GiB, **fits**.
  **Confirmed.**

### 3. llama-3.2-vision-11b x prefill_32k (most collective-bound)

| variant | collective GiB/dev | t_collective |
|---|---|---|
| baseline (TP activations, FSDP weights) | {lv_base_coll/2**30:.1f} | {lv_base_coll/HW.ICI_BW:.3f} s |
| + sequence-parallel activations (H1) | 17.2 | 0.370 s |
| + TP-only weights for serving (H2) | {lv_opt['coll_gib']:.1f} | {lv_opt['t_coll_ms']/1e3:.3f} s |

* H1 (HLO shows ~28 x 1 GiB f32 all-reduces: XLA fused the norms' f32
  upcast before the TP reduce, doubling payload; Megatron-style sequence
  parallelism replaces them with bf16 gather/scatter at S/16) ->
  1.044 -> 0.370 s (**2.8x**).  **Confirmed.**
* H2 (prefill never re-reads weights: per-layer FSDP all-gathers are pure
  waste at inference; keep weights TP-sharded, replicated over data) ->
  0.370 -> 0.321 s; params/device 4.3 -> 1.2 GiB.  **Confirmed.**
* Stopping: the cell is now compute-bound (t_compute 0.74 s > t_coll
  0.32 s); the remaining all-gathers are the KV re-gathers around
  attention — ring attention (collective-permute pipelining) is the next
  step and is left documented.

## §Paper — reproduction of the paper's own experiments

Measured on the real tensorized engine (batched lookups, µs/lookup);
learning/compaction totals use the virtual clock calibrated to the paper's
measured per-file build time (40 ms / ~175k-record file, §4.4.1) — see
DESIGN.md §8.  Scale: 2^18 keys / 2^17 ops per suite (paper: 64M/10M on a
20-core Xeon; this container is one CPU core).

Reproduction status vs the paper's claims:
* Fig 8: Search-step speedup 2.5x (paper ~2x); LoadData bytes 13.5x smaller
  (256-record block vs 19-record window) — the paper's two mechanisms.
* Fig 9: 1.06x-1.94x by dataset (paper 1.23x-1.78x); linear dataset fastest
  with exactly 1 segment/model; segment count ordering (linear < seg1% <
  seg10%) and the latency-vs-segments correlation reproduce.
* Fig 11/15: 1.0x-1.4x across request distributions and SOSD datasets
  (paper 1.5x-1.8x) — direction reproduced; our vectorized baseline is
  already gather-bound, so the model path's win is structurally smaller
  than vs. LevelDB's pointer-chasing binary search.
* Fig 13/Table 1: CBA matches always-learn's foreground time while learning
  fewer files; offline degrades under churn (63% baseline-path at 50%
  writes); level learning loses to file learning under writes.
* **Divergence**: Fig 10's negative-internal-lookup effect does not appear
  at this scale (neg=0 even random-loaded): our compactor settles the small
  tree into *disjoint* per-level key ranges, so FindFiles prunes every
  cross-level probe.  The paper's 64M-key tree retains cross-level overlap.
  The speedup ordering (random-load > sequential-load benefits) still
  reproduces via the indexing share of latency.
* **Divergence**: Bourbon-level is *slower* than file models in this engine
  (paper: up to 1.92x faster read-only).  The paper's level-model gain
  comes from skipping FindFiles; our vectorized FindFiles is a ~0.45 µs
  compare-count, while the level model pays a wide (64K-entry) segment
  bisect per probe.  At engine scale the paper's premise (FindFiles is
  expensive) does not hold — recorded as a negative result.

{paper_section(args.bench)}

## Beyond-paper deltas (summary)

1. Batched tensorized lookup engine (TPU-native; compare-count formulation)
   — the paper's per-op speedup band reproduced under a completely
   different execution model.
2. Range-partitioned distributed store with learned per-shard indexes +
   reduce-scatter result routing (§Perf 1) — the paper is single-node.
3. Learned session/prefix index inside a continuous-batching serving engine
   (serving/session_store.py).
4. sqrt(L) nested remat + bf16 accumulation making a 104B dense train fit
   256 v5e chips (§Perf 2).
5. Sequence-parallel + TP-only-weights serving rules (§Perf 3).
6. int8 cross-pod gradient compression (optim/grad_compress.py, tested).
"""
    OUT.write_text(md)
    print(f"wrote {OUT} ({len(md)} chars)")


if __name__ == "__main__":
    main()
