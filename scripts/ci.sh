#!/usr/bin/env bash
# CI entry point: install dev deps (best effort — the suite also runs on a
# bare image via the hypothesis fallback shim) and run the tier-1 tests.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -r requirements-dev.txt || \
    echo "WARN: pip install failed (offline?) — continuing with baked-in deps"

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q
