#!/usr/bin/env bash
# CI entry point: install dev deps (best effort — the suite also runs on a
# bare image via the hypothesis fallback shim) and run the tier-1 tests.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -r requirements-dev.txt || \
    echo "WARN: pip install failed (offline?) — continuing with baked-in deps"

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

# exercise the maintenance-scheduler path end to end (auto value-log GC +
# MANIFEST checkpointing) on a shrunk load
REPRO_BENCH_SMOKE=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run gc
