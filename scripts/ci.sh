#!/usr/bin/env bash
# CI entry point: install dev deps (best effort — the suite also runs on a
# bare image via the hypothesis fallback shim) and run the tier-1 tests.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -r requirements-dev.txt || \
    echo "WARN: pip install failed (offline?) — continuing with baked-in deps"

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

# exercise the maintenance-scheduler path end to end (auto value-log GC +
# MANIFEST checkpointing) on a shrunk load
REPRO_BENCH_SMOKE=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run gc

# distributed plane on a real multi-device mesh: a separate process so the
# host-platform device-count flag applies before jax initializes — runs the
# shard_map GET and the 4-shard ShardedStore tests that skip on one device
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q tests/test_distributed.py

# sharded durable store: kill mid-write, reopen from the shard directories
# (smoke scale; reports reopen-from-disk vs rebuild-from-scratch)
REPRO_BENCH_SMOKE=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run dist_recovery

# serving front end: the server + pipeline tests (admission, HotKeyCache
# invalidation, fleet maintenance coordination, dispatch/resolve split,
# in-flight epoch consistency, write barriers, backpressure) run in the
# tier-1 suite above; re-run them standalone so a serving regression is
# named, then the smoke serve benchmark (batched vs naive throughput,
# the pipelined arm vs the synchronous tick loop, fleet-stall with vs
# without the coordinator)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q tests/test_server.py tests/test_pipeline.py
REPRO_BENCH_SMOKE=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run serve
