#!/usr/bin/env bash
# CI entry point: install dev deps (best effort — the suite also runs on a
# bare image via the hypothesis fallback shim) and run the tier-1 tests.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -r requirements-dev.txt || \
    echo "WARN: pip install failed (offline?) — continuing with baked-in deps"

# static gates first — they are the cheapest and name the invariant they
# guard (see src/repro/analysis/README.md):
#   bourbonlint: zero unbaselined findings on src/repro, and no module
#   outside the dead-module allowlist may be unreachable
python scripts/lint.py --baseline .bourbonlint-baseline.json
python scripts/lint.py --report dead-modules
# mypy: strict on repro.analysis, checked on storage/obs (mypy.ini); the
# baked-in image may not ship mypy — warn-skip rather than install
if python -c "import mypy" 2>/dev/null; then
    python -m mypy --config-file mypy.ini \
        src/repro/analysis src/repro/storage src/repro/obs
else
    echo "WARN: mypy not installed — skipping type gate"
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q

# exercise the maintenance-scheduler path end to end (auto value-log GC +
# MANIFEST checkpointing) on a shrunk load
REPRO_BENCH_SMOKE=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run gc

# distributed plane on a real multi-device mesh: a separate process so the
# host-platform device-count flag applies before jax initializes — runs the
# shard_map GET and the 4-shard ShardedStore tests that skip on one device
XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q tests/test_distributed.py

# sharded durable store: kill mid-write, reopen from the shard directories
# (smoke scale; reports reopen-from-disk vs rebuild-from-scratch)
REPRO_BENCH_SMOKE=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run dist_recovery

# serving front end: the server + pipeline + observability tests
# (admission, HotKeyCache invalidation, fleet maintenance coordination,
# dispatch/resolve split, in-flight epoch consistency, write barriers,
# backpressure, registry/exporter round-trips, counter monotonicity
# across epoch events) run in the tier-1 suite above; re-run them
# standalone so a serving regression is named, then the smoke serve
# benchmark (batched vs naive throughput, the pipelined arm vs the
# synchronous tick loop, fleet-stall with vs without the coordinator,
# obs-on vs obs-off tracing overhead)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m pytest -x -q tests/test_server.py tests/test_pipeline.py \
    tests/test_obs.py
REPRO_BENCH_SMOKE=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run serve

# filter plane: zipf lookups at 0/25/50/75% guaranteed-miss ratios,
# filters on vs off — the miss-heavy arms must show the probe-count
# reduction and the speedup the plane exists for (diffed against the
# committed baseline below; the 50% arm carries the >=1.15x target)
REPRO_BENCH_SMOKE=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m benchmarks.run ycsb

# host I/O plane determinism gate: the threaded read path (io_workers 1
# and 4) and the group-commit WAL committer must produce byte-identical
# results to the inline path (io_workers=0) with epoch_violations == 0 —
# worker count and thread scheduling are performance knobs, never
# semantics
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python scripts/check_io_determinism.py

# filter plane zero-false-negative gate: filters on vs off must produce
# byte-identical found/value arrays on a mixed present/absent/deleted
# workload (both the host-answer path and the device maybe-mask path),
# and a reopened store must serve recovered filters with zero rebuilds —
# a bloom false positive costs probes, a false negative is data loss
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python scripts/check_filter_zero_fn.py

# observability overhead gate: serve bench with tracing enabled (on the
# threaded pipelined server — the I/O-pool path is traced too) must stay
# within 5% of the untraced arm (and every read-path stage must have
# sampled observations).  A shared-CPU container makes single runs noisy,
# so the cheap obs-only suite retries up to 3 times before failing.
obs_ok=0
for attempt in 1 2 3; do
    if REPRO_BENCH_SMOKE=1 PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python -m benchmarks.run serve_obs \
       && PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
        python scripts/check_obs_overhead.py \
        bench_artifacts/BENCH_serve_obs.json; then
        obs_ok=1
        break
    fi
    echo "WARN: obs overhead gate attempt ${attempt} failed; retrying"
done
[ "$obs_ok" = "1" ] || { echo "FAIL: obs overhead gate"; exit 1; }

# benchmark trajectory: diff every fresh artifact written above against
# the committed baselines (benchmarks/baselines/) with a ±25% noise
# band.  Warn-by-default — a shared-CPU container jitters absolute
# latencies — set REPRO_BENCH_STRICT=1 to make regressions fatal
python scripts/check_bench_regression.py \
    ${REPRO_BENCH_STRICT:+--strict}
