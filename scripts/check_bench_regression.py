#!/usr/bin/env python
"""Benchmark trajectory gate: fresh artifacts vs committed baselines.

CI re-runs every benchmark suite and overwrites
``bench_artifacts/BENCH_<suite>.json`` in the working tree; the version
at ``HEAD`` is the committed baseline.  This script diffs the two per
record (``us_per_call``, lower is better) and prints a trajectory
table, so a perf regression is *named* in the CI log next to the run
that introduced it instead of discovered archaeologically.

Noise discipline (a shared-CPU container jitters single runs):

* ``--tolerance`` (default 0.25): a record only counts as a regression
  / improvement when it moved more than ±25% against its baseline.
* ``--min-us`` (default 5.0): records where both sides are under the
  floor are timer noise — reported, never gated.
* a config mismatch (smoke vs full, different ``n_keys``/``n_ops``/
  ``batch``) makes the whole suite informational: the numbers are not
  comparable, so the table is printed but nothing is gated.

Warn-by-default: exit 0 with a WARN block unless ``--strict`` (or
``REPRO_BENCH_STRICT=1`` via ci.sh) makes regressions fatal.  Suites
and records with no baseline are "new" — never a failure, growth is
the point.

Usage::

    python scripts/check_bench_regression.py [artifact.json ...]
        [--baseline DIR] [--tolerance 0.25] [--min-us 5.0] [--strict]

With no artifact arguments, every ``BENCH_*.json`` under
``$REPRO_BENCH_ARTIFACTS`` (default ``bench_artifacts/``) is checked.
``--baseline DIR`` reads baselines from a directory instead of
``git show HEAD:`` (for comparing two saved artifact sets offline).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# config keys that must match for a latency comparison to mean anything
CONFIG_KEYS = ("smoke", "full", "n_keys", "n_ops", "batch")


def _load(path: str):
    with open(path, encoding="utf-8") as f:
        return json.load(f)


# committed baseline snapshots (bench_artifacts/ itself is gitignored —
# the working-tree artifacts are the *fresh* side of the diff)
BASELINE_DIR = os.path.join(REPO, "benchmarks", "baselines")


def load_baseline(path: str, baseline_dir: str | None):
    """Return (baseline_dict | None, source_label).  Resolution order:
    an explicit ``--baseline`` dir, the artifact's own committed
    content (``git show HEAD:``, for trees that track artifacts), then
    the committed snapshot under ``benchmarks/baselines/``."""
    name = os.path.basename(path)
    if baseline_dir:
        p = os.path.join(baseline_dir, name)
        try:
            return _load(p), p
        except (OSError, ValueError):
            return None, p
    rel = os.path.relpath(os.path.abspath(path), REPO).replace(os.sep, "/")
    try:
        out = subprocess.run(["git", "show", f"HEAD:{rel}"], cwd=REPO,
                             capture_output=True, check=False)
        if out.returncode == 0:
            return json.loads(out.stdout.decode("utf-8")), f"HEAD:{rel}"
    except (OSError, ValueError):
        pass
    p = os.path.join(BASELINE_DIR, name)
    try:
        return _load(p), os.path.relpath(p, REPO)
    except (OSError, ValueError):
        return None, p


def config_mismatch(base: dict, cur: dict) -> list[str]:
    b, c = base.get("config", {}), cur.get("config", {})
    return [f"{k}: {b.get(k)!r} -> {c.get(k)!r}"
            for k in CONFIG_KEYS if b.get(k) != c.get(k)]


def compare_suite(base: dict, cur: dict, tolerance: float,
                  min_us: float):
    """Rows of (name, base_us, cur_us, delta_pct|None, verdict)."""
    by_name = {r["name"]: r for r in base.get("results", ())}
    rows = []
    for r in cur.get("results", ()):
        name, cur_us = r["name"], float(r.get("us_per_call") or 0.0)
        b = by_name.pop(name, None)
        if b is None:
            rows.append((name, None, cur_us, None, "new"))
            continue
        base_us = float(b.get("us_per_call") or 0.0)
        if base_us <= 0.0 or cur_us <= 0.0:
            rows.append((name, base_us, cur_us, None, "n/a"))
        elif base_us < min_us and cur_us < min_us:
            rows.append((name, base_us, cur_us,
                         (cur_us / base_us - 1.0) * 100.0, "tiny"))
        else:
            delta = cur_us / base_us - 1.0
            verdict = ("regressed" if delta > tolerance
                       else "improved" if delta < -tolerance else "ok")
            rows.append((name, base_us, cur_us, delta * 100.0, verdict))
    for name in by_name:        # baseline-only: the record went away
        rows.append((name, float(by_name[name].get("us_per_call")
                                 or 0.0), None, None, "removed"))
    return rows


def _us(v) -> str:
    return "-" if v is None else f"{v:12.1f}"


def print_table(rows) -> None:
    print(f"  {'record':<40} {'baseline us':>12} {'current us':>12} "
          f"{'delta':>8}  verdict")
    for name, b, c, d, verdict in rows:
        ds = "-" if d is None else f"{d:+7.1f}%"
        print(f"  {name:<40} {_us(b):>12} {_us(c):>12} {ds:>8}  "
              f"{verdict}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("artifacts", nargs="*",
                    help="BENCH_*.json files (default: all under the "
                         "artifact dir)")
    ap.add_argument("--baseline", default=None, metavar="DIR",
                    help="read baselines from DIR instead of "
                         "'git show HEAD:'")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="noise band: |delta| beyond this fraction "
                         "counts (default 0.25)")
    ap.add_argument("--min-us", type=float, default=5.0,
                    help="records under this on both sides are timer "
                         "noise, never gated (default 5.0)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on regressions (default: warn only)")
    args = ap.parse_args(argv)

    paths = args.artifacts or sorted(glob.glob(os.path.join(
        os.environ.get("REPRO_BENCH_ARTIFACTS",
                       os.path.join(REPO, "bench_artifacts")),
        "BENCH_*.json")))
    if not paths:
        print("check_bench_regression: no artifacts found")
        return 0

    regressions, improvements = [], []
    for path in paths:
        try:
            cur = _load(path)
        except (OSError, ValueError) as exc:
            print(f"suite {os.path.basename(path)}: unreadable ({exc})")
            continue
        suite = cur.get("suite", os.path.basename(path))
        base, src = load_baseline(path, args.baseline)
        if base is None:
            print(f"suite {suite}: NEW (no baseline at {src})")
            continue
        mismatch = config_mismatch(base, cur)
        rows = compare_suite(base, cur, args.tolerance, args.min_us)
        if mismatch:
            print(f"suite {suite}: CONFIG MISMATCH vs {src} "
                  f"({'; '.join(mismatch)}) — informational only")
        else:
            print(f"suite {suite}: vs {src} "
                  f"(tolerance ±{args.tolerance:.0%}, "
                  f"floor {args.min_us}us)")
        print_table(rows)
        if not mismatch:
            regressions += [(suite, r) for r in rows
                            if r[4] == "regressed"]
            improvements += [(suite, r) for r in rows
                             if r[4] == "improved"]
        print()

    print(f"trajectory: {len(improvements)} improved, "
          f"{len(regressions)} regressed "
          f"(beyond ±{args.tolerance:.0%})")
    for suite, (name, b, c, d, _) in regressions:
        print(f"  REGRESSION {suite}:{name} {b:.1f}us -> {c:.1f}us "
              f"({d:+.1f}%)")
    if regressions and not args.strict:
        print("WARN: regressions above are non-fatal "
              "(re-run with --strict to gate)")
    return 1 if (regressions and args.strict) else 0


if __name__ == "__main__":
    sys.exit(main())
