#!/usr/bin/env python
"""CI gate: the filter plane must never change a single result bit.

A bloom false positive costs wasted probes; a false *negative* is data
loss — a present key reported absent because a filter screened it or the
level maybe-mask pruned the level holding it.  This script runs one
fixed mixed GET workload (present keys, guaranteed-absent keys, deleted
keys whose tombstones must still pass their filter, and batches both
under and over ``host_answer_max`` so the host-answer path and the
device maybe-mask path are each exercised) through two identically
loaded stores — filters on vs off — and fails unless every request's
found/value arrays are byte-identical.  The filters-on durable store is
then reopened from the MANIFEST so the recovered-filter path is held to
the same bar.

Exit status 0 = identical; 1 = any divergence (printed per request).
"""

from __future__ import annotations

import os
import shutil
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import LSMConfig, StoreConfig  # noqa: E402
from repro.core.filters import FilterConfig  # noqa: E402
from repro.core.store import BourbonStore  # noqa: E402

N_KEYS = 1 << 12
ROUNDS = 8


def _cfg(enabled: bool) -> StoreConfig:
    return StoreConfig(mode="bourbon", policy="cba",
                       filters=FilterConfig(enabled=enabled),
                       lsm=LSMConfig(memtable_cap=1 << 10, file_cap=1 << 11,
                                     l1_cap_records=1 << 13))


def _load(st: BourbonStore, keys: np.ndarray, dead: np.ndarray) -> None:
    for off in range(0, keys.shape[0], 1 << 11):
        st.put_batch(keys[off: off + (1 << 11)])
    st.delete_batch(dead)                 # tombstones must pass filters
    st.flush_all()
    st.learn_all()


def _requests(keys: np.ndarray, dead: np.ndarray) -> list[np.ndarray]:
    """Fixed probe batches: mixed present/absent/deleted at sizes that
    route through the host-answer path (small) and the padded device
    dispatch with the per-level maybe-mask (large)."""
    rng = np.random.default_rng(11)
    absent = keys + 1                     # odd gap keys: never inserted
    reqs = []
    for r in range(ROUNDS):
        size = 64 if r % 2 == 0 else 512  # straddle host_answer_max
        parts = [rng.choice(keys, size // 2),
                 rng.choice(absent, size // 4),
                 rng.choice(dead, size // 4)]
        reqs.append(np.concatenate(parts).astype(np.int64))
    reqs.append(absent[:512].copy())      # pure existence-check sweep
    reqs.append(keys[:512].copy())        # pure hit sweep
    return reqs


def _run(st: BourbonStore, reqs: list[np.ndarray]) -> list[tuple]:
    out = []
    for ks in reqs:
        found, vals = st.get_batch(ks)
        out.append((np.asarray(found).tobytes(),
                    np.asarray(vals).tobytes()))
    return out


def _diff(tag: str, ref: list[tuple], got: list[tuple]) -> bool:
    ok = True
    for i, ((f0, v0), (f1, v1)) in enumerate(zip(ref, got)):
        if f0 != f1:
            print(f"FAIL: {tag} found-mask diverges at request {i} "
                  f"(a screened or pruned key was present: false negative)")
            ok = False
        elif v0 != v1:
            print(f"FAIL: {tag} values diverge at request {i}")
            ok = False
    return ok


def main() -> int:
    rng = np.random.default_rng(3)
    keys = rng.permutation(np.arange(1, N_KEYS + 1, dtype=np.int64) * 4)
    dead = keys[:: 16].copy()             # every 16th key deleted again
    reqs = _requests(keys, dead)

    off = BourbonStore(_cfg(enabled=False))
    _load(off, keys, dead)
    ref = _run(off, reqs)
    off.close()

    d = tempfile.mkdtemp(prefix="bourbon_zerofn_")
    try:
        on = BourbonStore.open(os.path.join(d, "db"), _cfg(enabled=True))
        _load(on, keys, dead)
        got = _run(on, reqs)
        screened = on.filter_screened
        on.close()
        if not _diff("filters-on", ref, got):
            return 1
        if screened == 0:
            print("FAIL: filters-on arm screened nothing — the gate "
                  "did not exercise the filter plane")
            return 1
        print(f"filters-on: {len(reqs)} requests byte-identical, "
              f"{screened} keys screened pre-dispatch")

        # reopen: recovered filters (MANIFEST record + .bf sidecars) must
        # serve the same answers with zero rebuilds
        re = BourbonStore.open(os.path.join(d, "db"), _cfg(enabled=True))
        built = re.filters_built
        got2 = _run(re, reqs)
        re.close()
        if built != 0:
            print(f"FAIL: reopen rebuilt {built} filters (expected 0: "
                  f"recovered from MANIFEST)")
            return 1
        if not _diff("filters-on-reopened", ref, got2):
            return 1
        print(f"filters-on reopened: {len(reqs)} requests byte-identical, "
              f"0 filters rebuilt")
    finally:
        shutil.rmtree(d, ignore_errors=True)
    print(f"OK: filter plane zero-false-negative across "
          f"{sum(r.shape[0] for r in reqs)} probes")
    return 0


if __name__ == "__main__":
    sys.exit(main())
