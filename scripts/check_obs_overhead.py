#!/usr/bin/env python
"""CI gate over the serve_obs artifact (BENCH_serve_obs.json).

Passes iff the obs-on arm held its throughput (within_5pct on the
``serve/obs_overhead.*`` record), the causal-tracing arm held its
throughput too (``serve/obs_trace_overhead.*`` at the default
``trace_sample_every``, with spans actually recorded and zero epoch
violations), AND the traced run produced a sampled observation for
every read-path stage — a breakdown with silent stages would mean the
tracer is wired to the wrong call sites.

    python scripts/check_obs_overhead.py bench_artifacts/BENCH_serve_obs.json
"""

from __future__ import annotations

import json
import sys

# canonical stage set, kept in lockstep with repro.obs.READ_STAGES (the
# script must stay runnable without PYTHONPATH=src, so no import)
STAGES = ("admission", "coalesce", "cache_probe", "filter_probe", "dispatch",
          "compute", "resolve", "value_fetch")


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else \
        "bench_artifacts/BENCH_serve_obs.json"
    with open(path) as f:
        art = json.load(f)
    results = {r["name"]: r for r in art["results"]}

    overhead = [r for n, r in results.items()
                if n.startswith("serve/obs_overhead.")]
    if not overhead:
        print(f"FAIL: no serve/obs_overhead record in {path}")
        return 1
    rec = overhead[0]
    ratio = rec["fields"].get("ratio")
    if rec["fields"].get("within_5pct") != "True":
        print(f"FAIL: obs-on throughput ratio {ratio} below 0.95 "
              f"({rec['derived']})")
        return 1

    tr = [r for n, r in results.items()
          if n.startswith("serve/obs_trace_overhead.")]
    if not tr:
        print(f"FAIL: no serve/obs_trace_overhead record in {path}")
        return 1
    trec = tr[0]
    tratio = trec["fields"].get("ratio")
    if trec["fields"].get("within_5pct") != "True":
        print(f"FAIL: causal-tracing throughput ratio {tratio} below "
              f"0.95 ({trec['derived']})")
        return 1
    if float(trec["fields"].get("traced", 0)) <= 0 \
            or float(trec["fields"].get("spans", 0)) <= 0:
        print(f"FAIL: tracing arm recorded no spans ({trec['derived']})")
        return 1
    if float(trec["fields"].get("epoch_violations", 0)) != 0:
        print(f"FAIL: tracing arm saw epoch violations "
              f"({trec['derived']})")
        return 1

    missing = [s for s in STAGES
               if results.get(f"serve/obs_stage.{s}", {})
               .get("fields", {}).get("count", 0) <= 0]
    if missing:
        print(f"FAIL: stages with no sampled observations: {missing}")
        return 1

    snap = art.get("obs", {}).get("snapshot", {})
    if "server_stage_us" not in snap:
        print("FAIL: artifact carries no obs snapshot")
        return 1

    print(f"OK: obs overhead ratio={ratio}, tracing ratio={tratio}, "
          f"all {len(STAGES)} stages observed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
