"""Quickstart: the Bourbon learned-index store in 40 lines.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import BourbonStore, StoreConfig, LSMConfig, make_dataset

# a store with small files so compactions happen quickly
store = BourbonStore(StoreConfig(
    mode="bourbon", policy="cba",
    lsm=LSMConfig(memtable_cap=1 << 12, file_cap=1 << 13,
                  l1_cap_records=1 << 15),
    fetch_values=True))

# load 64K OSM-like keys in random order (values default to key-derived)
keys = make_dataset("osm", 1 << 16, seed=0)
store.put_batch(np.random.default_rng(0).permutation(keys))
store.flush_all()

# learn the sstables (PLR models, error bound delta=8)
n = store.learn_all()
print(f"learned {n} sstable models")

# batched GET: every lookup takes the learned path
probes = np.random.default_rng(1).choice(keys, 4096)
found, values = store.get_batch(probes)
assert found.all()
print(f"hit rate {found.mean():.3f}; first value bytes: {values[0][:4]}")

# negatives mostly die at the bloom filter (probes+1 may be real keys in
# clustered data — mask those out)
missing = probes + 1
truly_missing = ~np.isin(missing, keys)
found_n, _ = store.get_batch(missing)
print(f"false hits on truly-missing keys: "
      f"{int(found_n[truly_missing].sum())} / {int(truly_missing.sum())}")

s = store.stats()
print(f"files={s['n_files']} avg_segments={s['avg_segments']:.1f} "
      f"space_overhead={100 * s['space_overhead']:.2f}% "
      f"model_path={100 * s['model_path_frac']:.1f}%")
