"""End-to-end driver: train a ~100M-param qwen2-family model for a few
hundred steps on synthetic data with checkpointing.

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import dataclasses

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--d-model", type=int, default=512)
ap.add_argument("--layers", type=int, default=8)
args = ap.parse_args()

from repro.configs import get_config
from repro.data.pipeline import DataConfig, TokenDataset, synthetic_tokens
from repro.launch.steps import TrainConfig
from repro.train.trainer import Trainer, TrainerConfig

# ~100M params: d=512, 8L, vocab 32k
cfg = dataclasses.replace(
    get_config("qwen2-0.5b"), d_model=512, n_heads=8, n_kv_heads=2,
    d_ff=2048, vocab=32768, n_units=args.layers, dtype="float32",
    tie_embeddings=True)

ds = TokenDataset(synthetic_tokens(8_000_000, cfg.vocab),
                  DataConfig(seq_len=256, global_batch=8, vocab=cfg.vocab))
tr = Trainer(cfg, TrainerConfig(steps=args.steps, ckpt_every=50,
                                ckpt_dir="/tmp/repro_example_ckpt",
                                log_every=20,
                                train=TrainConfig(remat="none")), ds)
out = tr.run()
for step, loss in out["losses"]:
    print(f"step {step:5d}  loss {loss:.4f}")
first, last = out["losses"][0][1], out["losses"][-1][1]
assert last < first, "loss should decrease"
print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps")
