"""Range-partitioned distributed GET on a local mesh — the cluster-level
Bourbon read path (all-gather probes -> learned local lookup -> masked psum).

  PYTHONPATH=src python examples/distributed_get.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.datasets import make_dataset
from repro.core.distributed import (DistStoreConfig, build_dist_get,
                                    build_dist_state)
from repro.core.jaxcompat import make_mesh, set_mesh

keys = make_dataset("ar", 1 << 16, seed=2)
vptrs = np.arange(keys.shape[0], dtype=np.int64)
cfg = DistStoreConfig(n_keys=keys.shape[0], probe_batch=1 << 12)

mesh = make_mesh((jax.device_count(),), ("data",), axis_type="Explicit")
state = {k: jnp.asarray(v) for k, v in
         build_dist_state(keys, vptrs, mesh.size, cfg).items()}
fn = build_dist_get(mesh, cfg)

rng = np.random.default_rng(0)
probes = jnp.asarray(rng.choice(keys, cfg.probe_batch))
with set_mesh(mesh):
    found, vp = fn(state, probes)
print(f"devices={mesh.size} probes={cfg.probe_batch} "
      f"hit_rate={float(jnp.mean(found)):.3f}")
assert bool(jnp.all(found))
print("all probes answered by their owning range shard")
