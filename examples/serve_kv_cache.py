"""Serve a small model with batched requests through the continuous-batching
engine; the Bourbon learned index is the session -> KV-page table.

  PYTHONPATH=src python examples/serve_kv_cache.py
"""

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving.engine import EngineConfig, Request, ServingEngine

cfg = get_smoke_config("qwen2-0.5b")
params = init_params(cfg, jax.random.key(0))
eng = ServingEngine(cfg, params, EngineConfig(max_batch=4, max_seq=64),
                    session_policy="always")

rng = np.random.default_rng(0)
for i in range(16):
    prompt = rng.integers(0, cfg.vocab, int(rng.integers(3, 12))
                          ).astype(np.int32)
    eng.submit(Request(rid=5000 + i, prompt=prompt, max_new=6))

eng.run_until_drained()
st = eng.sessions.stats()
print(f"served 16 requests in {eng.steps} engine steps "
      f"(continuous batching, max_batch=4)")
print(f"session store: {st['n_records']} live records, "
      f"model-path fraction {st['model_path_frac']:.2f}, "
      f"files learned {st['files_learned']}")
